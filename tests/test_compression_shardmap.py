"""Cross-pod gradient compression inside shard_map (subprocess, 2 'pods')."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, shard_map
    from repro.optim.compression import psum_compressed

    mesh = make_mesh((2,), ("pod",))
    rng = np.random.default_rng(0)
    g_local = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)  # per-pod grads

    def reduce_with(method):
        def f(g):
            e0 = {"g": jnp.zeros_like(g)}
            out, e1 = psum_compressed({"g": g}, "pod", method=method,
                                      error_state=e0 if method == "int8_ef" else None)
            return out["g"], (e1 or e0)["g"]
        return jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                                 out_specs=(P("pod"), P("pod"))))

    exact, _ = reduce_with("none")(g_local)
    bf16, _ = reduce_with("bf16")(g_local)
    q8, err = reduce_with("int8_ef")(g_local)

    true_sum = np.asarray(g_local).sum(0)
    np.testing.assert_allclose(np.asarray(exact)[0], true_sum, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(bf16)[0], true_sum, rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(q8)[0], true_sum, rtol=5e-2, atol=5e-2)
    # error feedback carries the quantization residual for the next step
    assert float(np.abs(np.asarray(err)).mean()) > 0
    # compressed collective visible in HLO as bf16 all-reduce
    txt = reduce_with("bf16").lower(g_local).compile().as_text()
    assert "all-reduce" in txt
    print("OK")
""")


def test_compressed_psum_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo", timeout=420,
    )
    assert r.returncode == 0, (r.stdout + r.stderr)[-3000:]
    assert "OK" in r.stdout
