"""Substrate tests: data pipeline determinism, checkpoint atomicity/resume/
resharding, optimizer math, gradient compression, trainer fault tolerance."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec, TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.optim.adamw import adamw_step, init_train_state, lr_schedule
from repro.optim.compression import dequantize_int8, quantize_int8

TINY = ShapeSpec("tiny_train", "train", 32, 4)


def tiny_cfg():
    return reduced(get_config("granite-8b"), num_layers=2)


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------


def test_pipeline_deterministic_and_resumable():
    cfg = tiny_cfg()
    p1 = TokenPipeline(cfg, TINY, seed=7)
    batches = [p1.next_batch() for _ in range(5)]
    # fresh pipeline, fast-forwarded via state_dict
    p2 = TokenPipeline(cfg, TINY, seed=7)
    for _ in range(3):
        p2.next_batch()
    state = p2.state_dict()
    p3 = TokenPipeline(cfg, TINY, seed=7)
    p3.load_state_dict(state)
    np.testing.assert_array_equal(p3.next_batch()["tokens"], batches[3]["tokens"])
    np.testing.assert_array_equal(p3.next_batch()["targets"], batches[4]["targets"])
    # random access agrees with sequential
    np.testing.assert_array_equal(
        TokenPipeline(cfg, TINY, seed=7).batch_at(4)["tokens"], batches[4]["tokens"]
    )


def test_pipeline_targets_are_shifted_tokens():
    cfg = tiny_cfg()
    b = TokenPipeline(cfg, TINY, seed=1).next_batch()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    assert b["tokens"].max() < cfg.vocab_size


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                       weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0], jnp.float32)}
    state = init_train_state(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(60):
        g = jax.grad(loss)(state["params"])
        state, m = adamw_step(state, g, tcfg)
    assert float(loss(state["params"])) < 0.1
    assert float(m["grad_norm"]) >= 0


def test_lr_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(tcfg, jnp.int32(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4, rel=1e-3)
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)  # 10% floor


def test_grad_clip():
    tcfg = TrainConfig(grad_clip=1.0, warmup_steps=0, learning_rate=1.0,
                       weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_train_state(params)
    g = {"w": jnp.full((4,), 100.0)}
    new, m = adamw_step(state, g, tcfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    # post-clip effective |g| per element = 100 * (1/200) = 0.5 -> mu = 0.05
    np.testing.assert_allclose(np.asarray(new["mu"]["w"]), 0.05, rtol=1e-5)


def test_int8_quantization_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.51
    # error feedback: residual carries exactly the quantization error
    err = x - deq
    x2 = x + err
    q2, s2 = quantize_int8(x2)
    deq2 = dequantize_int8(q2, s2)
    assert float(jnp.mean(jnp.abs((deq + deq2) / 2 - x))) < float(jnp.mean(jnp.abs(deq - x)))


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------


def _state():
    return {
        "params": {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.int32(5),
    }


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    st = _state()
    ck.save(5, st, {"pipeline": {"seed": 1, "step": 5}})
    restored, extra = ck.restore(5, st)
    np.testing.assert_array_equal(np.asarray(restored["params"]["a"]),
                                  np.asarray(st["params"]["a"]))
    assert extra["pipeline"]["step"] == 5


def test_checkpoint_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _state())
    assert ck.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save_async(7, _state(), {"pipeline": {}})
    ck.wait()
    assert ck.latest_step() == 7


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(1, _state())
    # simulate a writer killed mid-checkpoint: tmp dir without manifest
    partial = tmp_path / "step_00000002.tmp"
    partial.mkdir()
    (partial / "leaf_00000.npy").write_bytes(b"garbage")
    # and a committed-looking dir missing its manifest
    broken = tmp_path / "step_00000003"
    broken.mkdir()
    assert ck.latest_step() == 1


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    st = _state()
    path = ck.save(4, st)
    leaf = sorted(path.glob("leaf_*.npy"))[0]
    arr = np.load(leaf)
    arr_flat = arr.reshape(-1)
    arr_flat[0] += 1
    np.save(leaf, arr)
    with pytest.raises(IOError, match="corruption"):
        ck.restore(4, st)


def test_checkpoint_reshard_on_restore(tmp_path):
    """Elastic drill: save unsharded, restore with an explicit sharding."""
    ck = Checkpointer(tmp_path, keep=1)
    st = _state()
    ck.save(1, st)
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), st
    )
    restored, _ = ck.restore(1, st, shardings=shardings)
    assert restored["params"]["a"].sharding == jax.sharding.SingleDeviceSharding(dev)
