"""Speculative decoding through the unified serve step.

The contract under test: greedy spec decode (n-gram OR draft-model
proposer) is BIT-IDENTICAL to the non-spec unified engine — the
equivalence oracle — across full/SWA/GQA/MoE configs; rejected drafts are
provably inert (rewind test: heavy rejection + rollback, pool conserved);
the EV_SPEC_DRAFTED/ACCEPTED/K counter triple survives the segment merge
with DRAFTED >= ACCEPTED per dispatch; temperature>0 runs are same-seed
reproducible; and mp=2 spec decode matches single-device bit-for-bit."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import events as ev
from repro.models.model import build_model
from repro.serve.spec import DraftModelProposer, NGramProposer, make_proposer
from repro.serve.step import UnifiedServeEngine

_CACHE = {}


def _setup(arch, **kw):
    key = (arch, tuple(sorted(kw.items())))
    if key not in _CACHE:
        cfg = reduced(get_config(arch), num_layers=2, **kw)
        model = build_model(cfg)
        _CACHE[key] = (cfg, model.init(jax.random.PRNGKey(0)))
    return _CACHE[key]


def _prompts(cfg, lens, seed=0, motif=None):
    rng = np.random.default_rng(seed)
    out = []
    for i, length in enumerate(lens):
        if motif is not None and i % 2 == 0:
            m = rng.integers(0, cfg.vocab_size, (motif,)).astype(np.int32)
            out.append(np.tile(m, -(-length // motif))[:length])
        else:
            out.append(rng.integers(0, cfg.vocab_size, (length,))
                       .astype(np.int32))
    return out


# ----------------------------------------------------------------------
# oracle equivalence: greedy spec == non-spec unified, bit for bit
# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch,kw,what", [
    ("granite-8b", {}, "full attention + GQA"),
    ("granite-8b", {"attention_window": 12}, "dense + SWA"),
    ("yi-9b", {}, "full attention + GQA 4:1"),
    ("mixtral-8x22b", {}, "SWA + GQA + MoE"),
])
def test_spec_ngram_matches_unified_oracle(arch, kw, what):
    """Repetitive AND random prompts (acceptances and rejections both
    exercised), lengths crossing chunk/block boundaries."""
    cfg, params = _setup(arch, **kw)
    prompts = _prompts(cfg, [24, 7, 17, 30], seed=2, motif=6)
    ref = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8)
    rr = [ref.submit(p, 10) for p in prompts]
    out_ref = ref.run()
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8,
                             spec=NGramProposer(), spec_k=4)
    rs = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    for a, b in zip(rr, rs):
        np.testing.assert_array_equal(out_ref[a.rid], out[b.rid], err_msg=what)
    assert eng.stats["spec_dispatches"] > 0
    assert eng.stats["spec_drafted"] >= eng.stats["spec_accepted"] >= 0


def test_spec_draft_model_matches_unified_oracle():
    """Draft-model proposer: a 1-layer cut-down config sharing the vocab.
    Random weights mean near-zero acceptance — the correctness claim is
    exactly that rejected drafts change nothing."""
    cfg, params = _setup("granite-8b")
    dcfg = reduced(get_config("granite-8b"),
                   num_layers=1).replace(vocab_size=cfg.vocab_size)
    dparams = build_model(dcfg).init(jax.random.PRNGKey(7))
    prompts = _prompts(cfg, [7, 18, 25], seed=3)
    ref = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8)
    rr = [ref.submit(p, 10) for p in prompts]
    out_ref = ref.run()
    prop = DraftModelProposer(dcfg, dparams, num_slots=2, max_len=64)
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8, spec=prop, spec_k=3)
    rs = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    for a, b in zip(rr, rs):
        np.testing.assert_array_equal(out_ref[a.rid], out[b.rid])


def test_spec_self_draft_accepts_everything():
    """Drafting with the TARGET's own weights must accept every draft
    (the proposer IS the verifier) — the positive control for the
    draft-model catch-up/rewind machinery: any cache-desync between
    proposals would break the all-accept property."""
    cfg, params = _setup("granite-8b")
    prop = DraftModelProposer(cfg, params, num_slots=2, max_len=64)
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8, spec=prop, spec_k=4)
    prompts = _prompts(cfg, [9, 22], seed=4)
    rs = [eng.submit(p, 12) for p in prompts]
    out = eng.run()
    assert all(len(out[r.rid]) == 12 for r in rs)
    assert eng.stats["spec_drafted"] > 0
    assert eng.stats["spec_accepted"] == eng.stats["spec_drafted"]
    ref = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8)
    rr = [ref.submit(p, 12) for p in prompts]
    out_ref = ref.run()
    for a, b in zip(rr, rs):
        np.testing.assert_array_equal(out_ref[a.rid], out[b.rid])


# ----------------------------------------------------------------------
# rewind: rejected drafts are inert, rolled-back blocks conserved
# ----------------------------------------------------------------------
def test_rejected_drafts_rewind_and_pool_conserved():
    """Tight pool + wide spans + near-total rejection: blocks allocated
    for rejected draft positions must roll back (the pool never charges
    speculation against the committed frontier), outputs stay bit-exact,
    and FREE/ACTIVE/CACHED conservation holds after drain."""
    cfg, params = _setup("granite-8b")
    ref = UnifiedServeEngine(cfg, params, num_slots=2, max_len=40,
                             block_size=8, chunk_size=8)
    prompts = _prompts(cfg, [9, 12], seed=5)
    rr = [ref.submit(p, 16) for p in prompts]
    out_ref = ref.run()
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=40,
                             block_size=8, num_blocks=12, chunk_size=8,
                             spec=NGramProposer(), spec_k=8,
                             max_step_tokens=40)
    rs = [eng.submit(p, 16) for p in prompts]
    out = eng.run()
    for a, b in zip(rr, rs):
        np.testing.assert_array_equal(out_ref[a.rid], out[b.rid])
    assert eng.stats["spec_rollback_blocks"] > 0, \
        "wide rejected spans never rolled a block back"
    eng.pool.check_invariants()
    assert eng.pool.num_active() == 0


def test_spec_decode_victim_preempted_by_chunk_planning():
    """Chunk planning runs AFTER span planning and can preempt a
    spec-planned decode victim (just-in-time chunk allocation, newest
    first): the victim's span must be dropped — budget counters never
    charge positions that did not dispatch, registers stay frozen — and
    every request still matches its uncontended solo run bit-for-bit."""
    from repro import core as xtrace

    cfg, params = _setup("granite-8b")
    tracer = xtrace.init("serve-spec-preempt")
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=40,
                             block_size=8, num_blocks=7, chunk_size=8,
                             chunk_rows=1, spec=NGramProposer(), spec_k=6,
                             max_step_tokens=40, tracer=tracer)
    prompts = _prompts(cfg, [16, 16], seed=8)
    gens = [24, 8]
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    out = eng.run()
    trace = tracer.finish()
    assert eng.stats["preemptions"] > 0
    evs = trace.events
    tri = {code: evs[evs["type"] == code]["value"]
           for code in (ev.EV_STEP_BUDGET, ev.EV_CHUNK_TOKENS,
                        ev.EV_DECODE_TOKENS)}
    np.testing.assert_array_equal(
        tri[ev.EV_STEP_BUDGET],
        tri[ev.EV_CHUNK_TOKENS] + tri[ev.EV_DECODE_TOKENS])
    assert (np.asarray(tri[ev.EV_STEP_BUDGET]) <= eng.max_step_tokens).all()
    for r, p, g in zip(reqs, prompts, gens):
        assert len(out[r.rid]) == g
        solo = UnifiedServeEngine(cfg, params, num_slots=1, max_len=40,
                                  block_size=8, chunk_size=8,
                                  spec=NGramProposer(), spec_k=6)
        s = solo.submit(p, g)
        np.testing.assert_array_equal(out[r.rid], solo.run()[s.rid],
                                      err_msg=f"req {r.rid}")
    eng.pool.check_invariants()
    assert eng.pool.num_active() == 0


def test_spec_adaptive_k_shrinks_under_rejection():
    """Random prompts reject nearly everything: the acceptance-rate EMA
    must walk K down to 1, and outputs must still match the oracle."""
    cfg, params = _setup("granite-8b")
    prompts = _prompts(cfg, [16, 11], seed=6)
    ref = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8)
    rr = [ref.submit(p, 24) for p in prompts]
    out_ref = ref.run()
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8,
                             spec=NGramProposer(), spec_k=6,
                             spec_adaptive=True, max_step_tokens=64)
    rs = [eng.submit(p, 24) for p in prompts]
    out = eng.run()
    for a, b in zip(rr, rs):
        np.testing.assert_array_equal(out_ref[a.rid], out[b.rid])
    assert eng._spec_k == 1, f"K stayed at {eng._spec_k} under total rejection"


# ----------------------------------------------------------------------
# trace counters: the draft economy survives the segment merge
# ----------------------------------------------------------------------
def test_spec_counters_per_dispatch_in_merged_prv(tmp_path):
    from repro import core as xtrace

    cfg, params = _setup("granite-8b")
    tracer = xtrace.init("serve-spec-counters")
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8,
                             spec=NGramProposer(), spec_k=4, tracer=tracer,
                             flush_every=4, flush_base=tmp_path / "spec")
    for p in _prompts(cfg, [24, 15, 9], seed=7, motif=6):
        eng.submit(p, 12)
    eng.run()
    segments = list(tracer.segments)
    trace = xtrace.finish()
    assert segments, "flush cadence never fired"
    paths = xtrace.write_prv(trace, tmp_path / "spec", segments=segments)
    merged = xtrace.parse_prv(paths["prv"])
    evs = merged.events
    by = {code: evs[evs["type"] == code]["value"]
          for code in (ev.EV_SPEC_DRAFTED, ev.EV_SPEC_ACCEPTED, ev.EV_SPEC_K)}
    n = len(by[ev.EV_SPEC_DRAFTED])
    assert n == eng.stats["spec_dispatches"] > 0
    assert all(len(v) == n for v in by.values())
    drafted = np.asarray(by[ev.EV_SPEC_DRAFTED], np.int64)
    accepted = np.asarray(by[ev.EV_SPEC_ACCEPTED], np.int64)
    rejected = drafted - accepted
    # the tentpole invariant, per dispatch, off the MERGED .prv
    assert (rejected >= 0).all() and (drafted == accepted + rejected).all()
    assert int(drafted.sum()) == eng.stats["spec_drafted"]
    assert int(accepted.sum()) == eng.stats["spec_accepted"]
    assert (np.asarray(by[ev.EV_SPEC_K]) >= 1).all()
    # the budget triple still holds in spec mode: draft+verify positions
    # are charged as decode tokens
    tri = {code: evs[evs["type"] == code]["value"]
           for code in (ev.EV_STEP_BUDGET, ev.EV_CHUNK_TOKENS,
                        ev.EV_DECODE_TOKENS)}
    np.testing.assert_array_equal(
        tri[ev.EV_STEP_BUDGET],
        tri[ev.EV_CHUNK_TOKENS] + tri[ev.EV_DECODE_TOKENS])
    assert (np.asarray(tri[ev.EV_STEP_BUDGET])
            <= eng.max_step_tokens).all()


# ----------------------------------------------------------------------
# temperature > 0: rejection sampling, reproducible per seed
# ----------------------------------------------------------------------
@pytest.mark.parametrize("make", ["ngram", "draft"])
def test_spec_sampling_same_seed_reproducible(make):
    cfg, params = _setup("granite-8b")
    dcfg = reduced(get_config("granite-8b"),
                   num_layers=1).replace(vocab_size=cfg.vocab_size)
    dparams = build_model(dcfg).init(jax.random.PRNGKey(7))

    def proposer():
        if make == "ngram":
            return NGramProposer()
        return DraftModelProposer(dcfg, dparams, num_slots=2, max_len=64,
                                  temperature=0.8, top_p=0.9, seed=11)

    prompts = _prompts(cfg, [9, 20], seed=8, motif=5)
    waves = []
    for _ in range(2):
        eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                                 block_size=16, chunk_size=8, spec=proposer(),
                                 spec_k=3, temperature=0.8, top_p=0.9,
                                 seed=11)
        rs = [eng.submit(p, 10) for p in prompts]
        out = eng.run()
        waves.append([out[r.rid] for r in rs])
    for a, b in zip(*waves):
        np.testing.assert_array_equal(a, b)


def test_make_proposer_factory():
    cfg, _ = _setup("granite-8b")
    assert isinstance(make_proposer("ngram", cfg, num_slots=2, max_len=32),
                      NGramProposer)
    prop = make_proposer("draft:granite-8b", cfg, num_slots=2, max_len=32)
    assert isinstance(prop, DraftModelProposer)
    assert prop.cfg.vocab_size == cfg.vocab_size
    with pytest.raises(ValueError, match="unknown --spec"):
        make_proposer("nope", cfg, num_slots=2, max_len=32)


def test_spec_rejects_state_carrying_families():
    cfg, params = _setup("recurrentgemma-9b")
    with pytest.raises(ValueError, match="speculative"):
        UnifiedServeEngine(cfg, params, num_slots=2, max_len=48,
                           spec=NGramProposer())


# ----------------------------------------------------------------------
# mp=2: spec decode over the mesh, bit-identical to single-device
# ----------------------------------------------------------------------
MP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, numpy as np
    from repro.compat import make_mesh
    from repro.configs import get_config, reduced
    from repro.models.model import build_model
    from repro.serve.spec import NGramProposer
    from repro.serve.step import UnifiedServeEngine

    mesh = make_mesh((1, 2), ("data", "model"))
    cfg = reduced(get_config("granite-8b"), num_layers=2, num_kv_heads=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    motif = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    prompts = [np.tile(motif, 4), rng.integers(
        0, cfg.vocab_size, (17,)).astype(np.int32)]

    ref = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8,
                             spec=NGramProposer(), spec_k=4)
    rr = [ref.submit(p, 10) for p in prompts]
    out_ref = ref.run()
    eng = UnifiedServeEngine(cfg, params, num_slots=2, max_len=64,
                             block_size=16, chunk_size=8,
                             spec=NGramProposer(), spec_k=4, mesh=mesh)
    rs = [eng.submit(p, 10) for p in prompts]
    out = eng.run()
    for a, b in zip(rr, rs):
        np.testing.assert_array_equal(out_ref[a.rid], out[b.rid])
    print("OK spec-mp2")
""")


def test_spec_mp_bit_identical():
    r = subprocess.run(
        [sys.executable, "-c", MP_SCRIPT], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo", timeout=560)
    assert r.returncode == 0, (r.stdout + r.stderr)[-3000:]
    assert "OK spec-mp2" in r.stdout
