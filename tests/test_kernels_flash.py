"""Flash-attention Pallas kernel vs pure-jnp oracle: shape/dtype/mask sweeps
in interpret mode (kernel body executes on CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import attention_ref, flash_attention


def _mk(b, sq, skv, hq, hkv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    return q, k, v


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


CASES = [
    # b, sq, skv, hq, hkv, d, causal, window, q_offset
    (2, 128, 128, 4, 4, 64, True, None, 0),      # MHA causal
    (2, 256, 256, 4, 1, 64, True, None, 0),      # MQA
    (1, 256, 256, 8, 2, 128, True, None, 0),     # GQA, d=128
    (1, 128, 128, 2, 2, 64, False, None, 0),     # bidirectional
    (1, 384, 384, 2, 1, 64, True, 128, 0),       # sliding window
    (2, 200, 200, 2, 2, 64, True, None, 0),      # non-multiple -> padding
    (1, 128, 384, 2, 2, 64, True, None, 256),    # chunked prefill (q_offset)
    (1, 64, 512, 4, 4, 64, True, 96, 448),       # SWA + offset
]


@pytest.mark.parametrize("case", CASES, ids=[str(i) for i in range(len(CASES))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(case, dtype):
    b, sq, skv, hq, hkv, d, causal, window, qoff = case
    q, k, v = _mk(b, sq, skv, hq, hkv, d, dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=qoff, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    assert out.shape == q.shape and out.dtype == q.dtype
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_flash_block_size_invariance():
    q, k, v = _mk(1, 256, 256, 2, 2, 64, jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_flash_matches_model_blocked_sdpa():
    """Kernel agrees with the model's online-softmax blocked SDPA path too."""
    import numpy as onp

    from repro.models.attention import multi_head_attention

    q, k, v = _mk(2, 256, 256, 4, 2, 64, jnp.float32)
    out_kernel = flash_attention(q, k, v, causal=True, interpret=True)
    out_model = multi_head_attention(
        q, k, v, q_pos=onp.arange(256, dtype=onp.int32),
        kv_pos=onp.arange(256, dtype=onp.int32), causal=True, block_kv=64,
    )
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-5, atol=2e-5)
