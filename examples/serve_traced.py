"""Serve a small model with batched requests, traced end-to-end.

Prefill + 48 decode steps over a batch of 8 requests through the
ServeEngine; the trace shows prefill/decode user-function regions and a
tokens-decoded counter, analyzed with the same tooling as training traces.

    PYTHONPATH=src python examples/serve_traced.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import core as xtrace
from repro.core import events as ev
from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.engine import ServeEngine

OUT = pathlib.Path(__file__).resolve().parent / "out"


def main():
    OUT.mkdir(exist_ok=True)
    # a sliding-window arch exercises the ring KV cache in serving
    cfg = reduced(get_config("mixtral-8x22b"), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tracer = xtrace.init("serve")
    engine = ServeEngine(cfg, params, max_len=128, tracer=tracer)

    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 32)).astype(np.int32)
    out = engine.generate(prompts, num_tokens=48, temperature=0.0)
    stats = engine.throughput_stats(prompts, num_tokens=48)

    trace = xtrace.finish()
    paths = xtrace.write_prv(trace, OUT / "serve")
    print(trace.summary())
    print(f"paraver: {paths['prv']}")
    print(f"generated shape: {out.shape}; throughput {stats['tok_per_s']:.1f} tok/s (CPU)")
    print("\nTime fractions per serving region:")
    for name, st in xtrace.time_fractions(trace, ev.EV_USER_FUNC).items():
        print(f"  {name:12s} {st['mean'] * 100:6.2f}%")


if __name__ == "__main__":
    main()
