"""Serve a small model through the unified token-budget step, traced
end-to-end.

8 variable-arrival requests flow through a 4-slot unified-step engine over
the paged KV-block pool (sliding-window arch — the window is a mask over
absolute positions, not a ring): each scheduler iteration mixes decode
tokens with chunked-prefill slices under a token budget, so prompts stream
in without head-of-line-blocking decode (docs/chunked_prefill.md).  The
trace records every scheduler AND allocator decision (queue depth, slot
occupancy, blocks free/cached, admit/retire, per-request TTFT/TPOT) plus
the per-iteration budget triple EV_STEP_BUDGET / EV_CHUNK_TOKENS /
EV_DECODE_TOKENS, and is streamed to disk mid-run (EV_FLUSH-bracketed
segments) then segment-merged into one Paraver trace — the prefill/decode
interleave is read back from the merged ``.prv`` below.

    PYTHONPATH=src python examples/serve_traced.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro import core as xtrace
from repro.core import events as ev
from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serve.step import UnifiedServeEngine

OUT = pathlib.Path(__file__).resolve().parent / "out"


def main():
    OUT.mkdir(exist_ok=True)
    # a sliding-window arch exercises the masked-window paged span path
    cfg = reduced(get_config("mixtral-8x22b"), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tracer = xtrace.init("serve")
    engine = UnifiedServeEngine(
        cfg, params, num_slots=4, max_len=128, chunk_size=16,
        tracer=tracer, flush_every=24, flush_base=OUT / "serve",
    )

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (8, 32)).astype(np.int32)
    reqs = [engine.submit(prompts[i], 48) for i in range(8)]
    results = engine.run()
    out = np.stack([results[r.rid] for r in reqs])
    stats = engine.throughput_stats()

    segments = list(tracer.segments)
    trace = xtrace.finish()
    paths = xtrace.write_prv(trace, OUT / "serve", segments=segments)
    print(trace.summary())
    print(f"paraver: {paths['prv']} (merged {len(segments)} flushed segments)")
    print(f"generated shape: {out.shape}; throughput {stats['tok_per_s']:.1f} tok/s (CPU)")
    print(f"host syncs: {stats['host_syncs']} for {stats['tokens_decoded']} tokens "
          f"over {stats['iterations']} decode iterations")
    for r in reqs[:3]:
        print(f"  req {r.rid}: ttft {r.ttft_ns() / 1e6:7.1f} ms   "
              f"tpot {r.tpot_ns() / 1e6:6.1f} ms")

    # analysis runs on the merged trace (reparse the .prv: flushed segments
    # are on disk, not in the in-memory Trace) — the budget counters prove
    # the chunked-prefill/decode interleave survived the segment merge
    merged = xtrace.parse_prv(paths["prv"])
    evs = merged.events
    by = {code: evs[evs["type"] == code]["value"]
          for code in (ev.EV_STEP_BUDGET, ev.EV_CHUNK_TOKENS,
                       ev.EV_DECODE_TOKENS)}
    mixed = int(((by[ev.EV_CHUNK_TOKENS] > 0)
                 & (by[ev.EV_DECODE_TOKENS] > 0)).sum())
    assert mixed > 0, "no mixed chunk+decode iteration in the merged .prv"
    print(f"\nbudget counters in merged .prv: {len(by[ev.EV_STEP_BUDGET])} "
          f"iterations, {mixed} mixing chunked prefill WITH decode "
          f"(peak step {int(by[ev.EV_STEP_BUDGET].max())} tokens "
          f"of budget {engine.max_step_tokens})")
    print("Time fractions per serving region (merged trace):")
    for name, st in xtrace.time_fractions(merged, ev.EV_USER_FUNC).items():
        print(f"  {name:12s} {st['mean'] * 100:6.2f}%")


if __name__ == "__main__":
    main()
