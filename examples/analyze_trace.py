"""Offline Paraver-trace analysis — the paper's "external post-processing"
workflow (and its future-work item of reparsing .prv natively).

    PYTHONPATH=src python examples/analyze_trace.py examples/out/distributed.prv
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro import core as xtrace
from repro.core import events as ev
from repro.core.analysis import ascii_matrix, ascii_series


def main(argv=None):
    argv = argv or sys.argv[1:]
    if not argv:
        default = pathlib.Path(__file__).resolve().parent / "out" / "distributed.prv"
        if not default.exists():
            print("usage: analyze_trace.py <trace.prv>  (or run "
                  "distributed_trace.py first)")
            return 1
        argv = [str(default)]
    trace = xtrace.parse_prv(argv[0])
    print(trace.summary())

    _, par = xtrace.parallelism_timeline(trace, buckets=72)
    print("\n[Fig 1] instantaneous parallelism")
    print(ascii_series(par, label="tasks running"))

    counts, sizes = xtrace.connectivity(trace)
    if counts.sum():
        print("\n[Fig 3] connectivity matrix")
        print(ascii_matrix(counts, label="messages"))

    for etype, tag in ((ev.EV_COLLECTIVE, "collectives"), (ev.EV_PHASE, "phases"),
                       (ev.EV_USER_FUNC, "user functions")):
        fr = xtrace.time_fractions(trace, etype)
        if fr:
            print(f"\n[Fig 4] time fractions — {tag}:")
            for name, st in sorted(fr.items(), key=lambda kv: -kv[1]["mean"]):
                print(f"  {name:22s} {st['mean'] * 100:6.2f}% (+-{st['std'] * 100:.2f})")

    _, series, peak = xtrace.bandwidth_timeline(trace, buckets=72)
    if peak:
        print(f"\n[Fig 5] peak node bandwidth: {peak:.2f} MB/s")
    print("\n[what-if] Dimemas-style bandwidth sweep (predicted speedup):")
    for f, sp in xtrace.bandwidth_sweep(trace).items():
        print(f"  {f:>5.1f}x links -> {sp:5.3f}x")
    rep = xtrace.straggler_report(trace)
    if rep.median_ms:
        print(f"\nstragglers: {rep.stragglers or 'none'} "
              f"(median step {rep.median_ms:.2f} ms)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
