"""End-to-end training driver: train an LM for a few hundred steps with the
full substrate — data pipeline, AdamW, tracing, async checkpointing,
auto-resume — and report the loss curve.

    PYTHONPATH=src python examples/train_e2e.py                 # ~20M params
    PYTHONPATH=src python examples/train_e2e.py --preset 100m   # ~100M params
    PYTHONPATH=src python examples/train_e2e.py --steps 50 --arch mamba2-370m
"""
import argparse
import pathlib
import shutil
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro import core as xtrace
from repro.core import events as ev
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec, TrainConfig
from repro.train.trainer import Trainer

OUT = pathlib.Path(__file__).resolve().parent / "out"

PRESETS = {
    # name -> (overrides, shape, steps)
    "small": (dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                   head_dim=32, d_ff=1024, vocab_size=8192), ShapeSpec("e2e", "train", 128, 8), 150),
    "100m": (dict(num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
                  head_dim=64, d_ff=2048, vocab_size=32_000), ShapeSpec("e2e", "train", 256, 8), 300),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--resume", action="store_true",
                    help="keep the workdir and auto-resume (default: fresh run)")
    args = ap.parse_args(argv)

    overrides, shape, steps = PRESETS[args.preset]
    steps = args.steps or steps
    cfg = reduced(get_config(args.arch), **overrides)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=20, total_steps=steps,
                       checkpoint_every=50, async_checkpoint=True)

    workdir = OUT / f"e2e_{args.arch}_{args.preset}"
    if not args.resume:
        shutil.rmtree(workdir, ignore_errors=True)
    tracer = xtrace.init("train-e2e")
    trainer = Trainer(cfg, tcfg, shape, workdir, tracer=tracer)
    trainer.install_preemption_handler()
    hist = trainer.run(steps)
    trace = xtrace.finish()
    xtrace.write_prv(trace, OUT / "train_e2e")

    n = trainer.model.param_count()
    print(f"\narch={args.arch} preset={args.preset}: {n / 1e6:.1f}M params, "
          f"{len(hist)} steps, compile {trainer.compile_time_s:.1f}s")
    for i in range(0, len(hist), max(len(hist) // 10, 1)):
        h = hist[i]
        print(f"  step {h['step']:4d}  loss {h['loss']:7.4f}  "
              f"xent {h['xent']:7.4f}  {h['time_s'] * 1e3:7.1f} ms")
    print(f"  step {hist[-1]['step']:4d}  loss {hist[-1]['loss']:7.4f}  (final)")
    first = sum(h["loss"] for h in hist[:10]) / min(10, len(hist))
    last = sum(h["loss"] for h in hist[-10:]) / min(10, len(hist))
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'LEARNED' if last < first else 'no improvement'})")
    print(f"checkpoints: {trainer.ckpt.all_steps()}")
    fr = xtrace.time_fractions(trace, ev.EV_PHASE)
    step_frac = fr.get("train_step", {"mean": 0})["mean"]
    print(f"step-time fraction of wall clock: {step_frac * 100:.1f}%")


if __name__ == "__main__":
    main()
