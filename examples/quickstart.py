"""Quickstart — Extrae.jl Listings 1 & 2, transposed to JAX.

Traces a small training run with user-function annotations and custom
events, then writes Paraver (.prv/.pcf/.row) and Chrome-trace files and
prints the time-fraction analysis.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import shutil

import jax
import jax.numpy as jnp

from repro import core as xtrace
from repro.core import events as ev
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec, TrainConfig
from repro.train.trainer import Trainer

OUT = pathlib.Path(__file__).resolve().parent / "out"


def main():
    OUT.mkdir(exist_ok=True)
    tracer = xtrace.init("quickstart")

    # ---- Listing 2 parity: custom event registration + emission ----
    CODE_VEC_LEN = 84210
    tracer.register(CODE_VEC_LEN, "Vector length")

    # ---- Listing 1 parity: @user_function on a hot region ----
    @tracer.user_function
    def axpy(a, x, y):
        tracer.emit(CODE_VEC_LEN, x.shape[0])
        return a * x + y

    x = jnp.ones((1 << 16,))
    y = jnp.zeros((1 << 16,))
    for t in (jnp.float16, jnp.float32, jnp.float64):
        axpy(jnp.asarray(2.0, t), x.astype(t), y.astype(t)).block_until_ready()

    # ---- trace a real (tiny) training run through the same tracer ----
    cfg = reduced(get_config("granite-8b"), num_layers=2)
    tcfg = TrainConfig(total_steps=8, checkpoint_every=4, warmup_steps=2,
                       learning_rate=1e-3, async_checkpoint=False)
    workdir = OUT / "quickstart_work"
    shutil.rmtree(workdir, ignore_errors=True)  # fresh demo run (no resume)
    trainer = Trainer(cfg, tcfg, ShapeSpec("qs", "train", 64, 4),
                      workdir, tracer=tracer)
    tracer.start_sampler(period_s=0.005, jitter_s=0.001)
    hist = trainer.run(8)

    trace = xtrace.finish()
    paths = xtrace.write_prv(trace, OUT / "quickstart")
    chrome = xtrace.write_chrome_trace(trace, OUT / "quickstart.chrome.json")

    print(trace.summary())
    print(f"paraver: {paths['prv']}  (+.pcf/.row)")
    print(f"chrome:  {chrome}")
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print("\nTime fractions per trainer phase (paper Fig 4 analogue):")
    for name, st in xtrace.time_fractions(trace, ev.EV_PHASE).items():
        print(f"  {name:12s} {st['mean'] * 100:6.2f}% (+-{st['std'] * 100:.2f})")
    n_samples = (trace.events["type"] == ev.EV_SAMPLE_FUNC).sum()
    print(f"\nsampler: {n_samples} stack samples")
    vec = trace.events[trace.events["type"] == CODE_VEC_LEN]
    print(f"custom events: {len(vec)} x 'Vector length' = {set(vec['value'])}")


if __name__ == "__main__":
    main()
