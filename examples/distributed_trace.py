"""The paper's section-4 evaluation, transposed: trace a *distributed* JAX
training job and analyze it with the Paraver-model analyses (Figs 1-5).

Where the paper traces a 16-rank MPI Taylor-Green vortex run, we trace a
16-device (4 data x 4 model) sharded LM training job: host-side phases are
captured live, and the compiled step's exact collective schedule (the
LD_PRELOAD-interception analogue, from the optimized HLO) is replayed onto
each measured step window as states + events + communication records.

    PYTHONPATH=src python examples/distributed_trace.py
"""
import os
import pathlib
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as xtrace
from repro.core import events as ev
from repro.core.analysis import ascii_matrix, ascii_series
from repro.configs import get_config, reduced
from repro.configs.base import ShapeSpec, TrainConfig
from repro.core.hlo_comm import parse_collectives
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build_model
from repro.optim.adamw import init_train_state, train_state_axes
from repro.sharding.partition import make_rules, use_rules
from repro.train.step import make_train_step


def main(num_steps: int = 6):
    out = pathlib.Path(__file__).resolve().parent / "out"
    out.mkdir(exist_ok=True)
    mesh = make_debug_mesh(data=4, model=2)
    cfg = reduced(get_config("granite-8b"), num_layers=4, d_model=128,
                  num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256)
    shape = ShapeSpec("dist", "train", 64, 8)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2)
    model = build_model(cfg)
    rules = make_rules(cfg, mesh, shape)

    endpoint_map = xtrace.device_endpoint_map(
        mesh, task_axes=("data",), thread_axes=("model",)
    )

    with use_rules(rules):
        step_fn = make_train_step(model, tcfg, microbatches=1)
        state_sh = rules.tree_shardings(train_state_axes(model.param_axes()))
        batch_axes = model.batch_axes()
        params = model.init(jax.random.PRNGKey(0))
        state = jax.device_put(init_train_state(params), state_sh)
        # NOTE: no donation here — XLA CPU's in-process SPMD runtime mishandles
        # donated replicated shards (fine on TPU; the dry-run keeps donation
        # since it only compiles).
        jit_step = jax.jit(step_fn, in_shardings=(state_sh, None),
                           out_shardings=(state_sh, None))

        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (64, 8)), jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (64, 8)), jnp.int32),
            "loss_mask": jnp.ones((64, 8), jnp.float32),
        }
        compiled = jit_step.lower(state, batch).compile()
        ops = parse_collectives(compiled.as_text(), total_devices=mesh.size)
        print(f"compiled schedule: {len(ops)} collectives "
              f"({sorted({o.kind for o in ops})})")
        # warm up, then trace only the steady-state steps (Extrae practice:
        # start tracing after initialization)
        state, _ = jit_step(state, batch)

        tracer = xtrace.init("distributed-train", mode="mesh_data")
        tracer.pm.bind_mesh(mesh, task_axes=("data",), thread_axes=("model",))

        # real steps; replay the compiled collective schedule per step window
        for s in range(num_steps):
            t0 = time.perf_counter_ns()
            with tracer.phase(ev.PHASE_STEP, step=s):
                state, metrics = jit_step(state, batch)
                jax.block_until_ready(metrics["loss"])
            t1 = time.perf_counter_ns()
            xtrace.replay_step(tracer, ops, t0, t1, endpoint_map, step=s)
            from repro.core.comm_replay import replay_running_gaps

            replay_running_gaps(tracer, endpoint_map, t0, t1)

    trace = xtrace.finish()
    paths = xtrace.write_prv(trace, out / "distributed")
    xtrace.write_chrome_trace(trace, out / "distributed.chrome.json")
    print(trace.summary())
    print(f"paraver: {paths['prv']}")

    # ---- the five paper analyses ----
    centers, par = xtrace.parallelism_timeline(trace, buckets=72)
    print("\nFig 1 — instantaneous parallelism (tasks running):")
    print(ascii_series(par, label="parallelism"))

    tl = xtrace.routine_timeline(trace, ev.EV_COLLECTIVE)
    print(f"\nFig 2 — per-rank collective timeline: rank0 has {len(tl[0])} intervals")

    counts, sizes = xtrace.connectivity(trace)
    print("\nFig 3 — connectivity (messages rank->rank):")
    print(ascii_matrix(counts, label="connectivity"))

    print("\nFig 4 — time fraction per collective routine:")
    for name, st in xtrace.time_fractions(trace, ev.EV_COLLECTIVE).items():
        print(f"  {name:20s} {st['mean'] * 100:6.2f}% (+-{st['std'] * 100:.2f})")

    centers, series, peak = xtrace.bandwidth_timeline(trace, buckets=72, by="node")
    print("\nFig 5 — node bandwidth (MB/s):")
    print(ascii_series(series.sum(0), label="bandwidth"))
    print(f"peak {peak:.1f} MB/s vs theoretical link 50 GB/s "
          f"(= {peak / 50e3 * 100:.3f}% — dry-run replay scale)")
    print(f"\nfinal loss {float(metrics['loss']):.4f}")
    return trace


if __name__ == "__main__":
    main()
