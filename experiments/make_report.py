"""Render EXPERIMENTS.md tables from the dry-run/hillclimb JSONs.

    PYTHONPATH=src python experiments/make_report.py
"""
import json
import pathlib
import sys

HERE = pathlib.Path(__file__).resolve().parent


def fmt(v):
    if isinstance(v, bool):
        return "Y" if v else "N"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) < 1e-3 or abs(v) >= 1e5:
            return f"{v:.2e}"
        return f"{v:.3f}"
    return str(v)


def roofline_table(rows, mesh):
    cols = ["arch", "shape", "dominant", "compute_s", "memory_s",
            "collective_s", "useful_ratio", "roofline_fraction", "fits_hbm"]
    head = ("| " + " | ".join(["arch", "shape", "dom", "compute s", "memory s",
                               "coll s", "useful", "roofline frac", "fits"])
            + " |")
    sep = "|" + "---|" * 9
    out = [head, sep]
    for r in rows:
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        out.append("| " + " | ".join(fmt(r[c]) for c in cols) + " |")
    return "\n".join(out)


def dryrun_table(rows, mesh):
    out = ["| arch | shape | microbatches | flops/dev | bytes/dev | coll bytes/dev"
           " | collectives | temp GiB | args GiB | compile s |",
           "|" + "---|" * 10]
    for r in rows:
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        coll = " ".join(f"{k}:{v}" for k, v in sorted(r["coll_by_kind"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('microbatches', '-')} "
            f"| {r['flops_dev']:.3e} | {r['bytes_dev']:.3e} "
            f"| {r['coll_operand_bytes_dev']:.3e} | {coll} "
            f"| {r['temp_bytes_dev'] / 2**30:.2f} | {r['arg_bytes_dev'] / 2**30:.2f} "
            f"| {r.get('compile_s', 0)} |")
    return "\n".join(out)


def main():
    src = sys.argv[1] if len(sys.argv) > 1 else "dryrun_baseline.json"
    rows = json.load(open(HERE / src))
    print("### Roofline — single pod (16d x 16m, 256 chips)\n")
    print(roofline_table(rows, "16dx16m"))
    print("\n### Roofline — multi-pod (2p x 16d x 16m, 512 chips)\n")
    print(roofline_table(rows, "2px16dx16m"))
    print("\n### Dry-run detail — single pod\n")
    print(dryrun_table(rows, "16dx16m"))
    print("\n### Dry-run detail — multi-pod\n")
    print(dryrun_table(rows, "2px16dx16m"))
    skipped = [r for r in rows if r.get("status") == "skipped" and r["mesh"] == "16dx16m"]
    print("\n### Skipped cells (same set on both meshes)\n")
    for r in skipped:
        print(f"- `{r['arch']} x {r['shape']}` — {r['reason']}")


if __name__ == "__main__":
    main()
